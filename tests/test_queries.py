"""Point location + k-NN (paper §V-A)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import queries


def test_point_location_exact(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    sel = rng.choice(2048, 256, replace=False)
    q = pts[jnp.asarray(sel)]
    found, gid, ok = queries.point_location(idx, q)
    assert bool(found.all()) and bool(ok.all())
    # returned ids identify coordinates equal to the query
    np.testing.assert_array_equal(np.asarray(pts)[np.asarray(gid)], np.asarray(q))


def test_point_location_misses(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    q = jnp.asarray(rng.random((256, 3)) + 2.0, jnp.float32)  # outside bbox
    found, gid, ok = queries.point_location(idx, q)
    assert not bool(found.any())
    assert (np.asarray(gid) == -1).all()
    assert bool(ok.all())  # certified misses: the key runs were fully scanned


def test_point_location_duplicate_heavy(rng):
    """>bucket_cap points sharing one SFC key (one quantization cell):
    the scan must either find the match or flag the miss as uncertified —
    never miss silently (the pre-CurveIndex bug)."""
    base = np.full((200, 3), 0.5, np.float32)
    base += rng.random((200, 3)).astype(np.float32) * 1e-5  # one cell at bits=10
    rest = rng.random((1848, 3)).astype(np.float32)
    pts = jnp.asarray(np.concatenate([base, rest]))
    idx = queries.build_index(pts, bucket_size=32)
    q = pts[:200]
    found, gid, ok = queries.point_location(idx, q, bucket_cap=64)
    # every miss is flagged: found | ~ok covers all queries
    assert bool((found | ~ok).all())
    # raising the cap past the run length resolves every query exactly
    found2, gid2, ok2 = queries.point_location(idx, q, bucket_cap=256)
    assert bool(found2.all()) and bool(ok2.all())
    np.testing.assert_array_equal(np.asarray(pts)[np.asarray(gid2)], np.asarray(q))


def test_pallas_key_search_matches_jnp(rng):
    """The bucket_search-kernel path (fused key-gen + directory search,
    full-key run search) must agree with the jnp.searchsorted fallback."""
    pts = jnp.asarray(rng.random((1024, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=16)
    q = jnp.concatenate([pts[:64], jnp.asarray(rng.random((64, 3)), jnp.float32)])
    b_ref = queries.locate_bucket(idx, q, use_pallas=False)
    b_pal = queries.locate_bucket(idx, q, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_pal))
    r_ref = queries.point_location(idx, q, use_pallas=False)
    r_pal = queries.point_location(idx, q, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(r_ref.found), np.asarray(r_pal.found))
    np.testing.assert_array_equal(np.asarray(r_ref.ids), np.asarray(r_pal.ids))


@pytest.mark.parametrize("k", [pytest.param(1, marks=pytest.mark.slow), 3, pytest.param(5, marks=pytest.mark.slow)])
def test_knn_recall(k, rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=32)
    q = jnp.asarray(rng.random((128, 3)), jnp.float32)
    d_a, id_a = queries.knn(idx, q, k=k, cutoff_buckets=2)
    d_b, id_b = queries.knn_bruteforce(pts, q, k=k)
    recall = float(
        jnp.mean(jnp.any(id_a[:, :, None] == id_b[:, None, :], axis=1).astype(jnp.float32))
    )
    assert recall > 0.7, f"recall@{k}: {recall}"  # CUTOFF-bounded approximate k-NN


def test_knn_distances_sorted_and_valid(rng):
    pts = jnp.asarray(rng.random((2048, 2)), jnp.float32)
    idx = queries.build_index(pts)
    q = jnp.asarray(rng.random((64, 2)), jnp.float32)
    d, ids = queries.knn(idx, q, k=3)
    d = np.asarray(d)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    assert np.isfinite(d).all()


def test_knn_window_covers_large_buckets(rng):
    """Candidate window derived from true bucket extents: with
    bucket_size > the old fixed 64-slot cap, clustered data must still
    reach full self-recall (the truncation bug regression test)."""
    cl = 0.3 + 0.05 * rng.random((1500, 3)).astype(np.float32)  # dense cluster
    rest = rng.random((548, 3)).astype(np.float32)
    pts = jnp.asarray(np.concatenate([cl, rest]))
    idx = queries.build_index(pts, bucket_size=128)
    assert idx.max_bucket_len > 64  # the regime the old window undercovered
    q = pts[:256]
    d, ids = queries.knn(idx, q, k=1, cutoff_buckets=1)
    # nearest neighbor of a stored point is itself — fails if the window
    # stops short of the true bucket extent
    assert float(np.asarray(d).max()) <= 1e-6
    d3, id3 = queries.knn(idx, q[:64], k=3, cutoff_buckets=2)
    d_b, id_b = queries.knn_bruteforce(pts, q[:64], k=3)
    recall = float(np.mean(np.any(
        np.asarray(id3)[:, :, None] == np.asarray(id_b)[:, None, :], axis=1)))
    assert recall > 0.7, recall


@given(n=st.integers(100, 2000), seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_property_self_query_returns_self(n, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    idx = queries.build_index(pts, bucket_size=16)
    q = pts[:64]
    d, ids = queries.knn(idx, q, k=1, cutoff_buckets=1)
    assert float(d.max()) <= 1e-6  # nearest neighbor of a stored point is itself
