"""Greedy knapsack slicing: the paper's load-balance guarantee as a
property test (§III-C)."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import knapsack


@given(
    n=st.integers(10, 5000),
    p=st.integers(1, 64),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=30, deadline=None)
def test_balance_guarantee(n, p, seed):
    """max load - min load <= 2 * max element weight (midpoint rule);
    the paper's bound is one max-weight, achieved for unit weights."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.random(n).astype(np.float32) + 0.01)
    part = knapsack.slice_weighted_curve(w, p)
    assert bool((jnp.diff(part) >= 0).all()), "parts must be contiguous on the curve"
    loads = np.asarray(knapsack.part_loads(w, part, p))
    maxw = float(jnp.max(w))
    assert loads.max() - loads.min() <= 2 * maxw + 1e-4


def test_unit_weights_perfect_balance():
    w = jnp.ones(1024, jnp.float32)
    part = knapsack.slice_weighted_curve(w, 16)
    loads = np.asarray(knapsack.part_loads(w, part, 16))
    assert loads.max() - loads.min() <= 1.0 + 1e-6  # paper's exact bound


def test_boundaries_consistent():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.random(500).astype(np.float32))
    part = np.asarray(knapsack.slice_weighted_curve(w, 7))
    bounds = np.asarray(knapsack.part_boundaries(w, 7))
    assert bounds[0] == 0 and bounds[-1] == 500
    for p in range(7):
        seg = part[bounds[p] : bounds[p + 1]]
        assert (seg == p).all() or seg.size == 0


def test_greedy_bins_balances():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.random(200).astype(np.float32) + 0.1)
    bins = np.asarray(knapsack.greedy_bins(w, 8))
    loads = np.bincount(bins, weights=np.asarray(w), minlength=8)
    assert loads.max() - loads.min() <= float(jnp.max(w)) + 1e-5


def test_incremental_reslice_neighbor_locality():
    """Paper §IV: small load changes move data only between rank
    neighbors P±1."""
    from repro.core import migration

    rng = np.random.default_rng(3)
    w0 = np.ones(4096, np.float32)
    old = np.asarray(knapsack.slice_weighted_curve(jnp.asarray(w0), 16))
    w1 = w0.copy()
    w1[rng.choice(4096, 200, replace=False)] *= 1.5  # mild load drift
    new, moved = knapsack.incremental_reslice(jnp.asarray(w1), jnp.asarray(old), 16)
    plan = migration.migration_plan(old, np.asarray(new), 16)
    if plan.total_moved:
        assert migration.neighbor_locality(plan) == 1.0
    assert plan.stay_fraction > 0.9
