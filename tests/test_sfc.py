"""SFC key generation: correctness + locality properties (paper §III-B)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import sfc


def test_hilbert_2d_base_case():
    """bits=1 in 2-D must give the canonical U curve."""
    pts = jnp.array([[0.0, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]])
    perm, _ = sfc.sfc_order(pts, curve="hilbert", bits=1)
    visited = np.asarray(pts)[np.asarray(perm)]
    assert visited.tolist() == [[0, 0], [0, 1], [1, 1], [1, 0]]


def test_hilbert_continuity_2d():
    """Consecutive Hilbert cells on a full grid are grid neighbors
    (the defining property; Morton violates it)."""
    bits = 4
    g = np.arange(2**bits)
    xx, yy = np.meshgrid(g, g, indexing="ij")
    pts = jnp.asarray(np.stack([xx.ravel(), yy.ravel()], 1), jnp.float32)
    perm, _ = sfc.sfc_order(pts, curve="hilbert", bits=bits)
    walk = np.asarray(pts)[np.asarray(perm)]
    jumps = np.abs(np.diff(walk, axis=0)).sum(axis=1)
    assert (jumps == 1).all(), f"max jump {jumps.max()}"


@pytest.mark.parametrize(
    "d",
    [2, pytest.param(3, marks=pytest.mark.slow), pytest.param(5, marks=pytest.mark.slow), 10],
)
def test_hilbert_beats_morton_locality(d, rng):
    pts = jnp.asarray(rng.random((4096, d)), jnp.float32)
    pm, _ = sfc.sfc_order(pts, curve="morton")
    ph, _ = sfc.sfc_order(pts, curve="hilbert")
    lm = float(sfc.locality_score(pts, pm))
    lh = float(sfc.locality_score(pts, ph))
    assert lh < lm, f"hilbert {lh} !< morton {lm} in d={d}"


@pytest.mark.parametrize("curve", ["morton", "hilbert"])
def test_keys_deterministic_and_bijective_on_grid(curve, rng):
    bits, d = 5, 2
    g = np.arange(2**bits)
    xx, yy = np.meshgrid(g, g, indexing="ij")
    cells = jnp.asarray(np.stack([xx.ravel(), yy.ravel()], 1), jnp.uint32)
    fn = sfc.morton_key_from_cells if curve == "morton" else sfc.hilbert_key_from_cells
    keys = np.asarray(fn(cells, bits))
    assert len(np.unique(keys)) == len(keys), "keys must be unique on a full grid"


@given(
    n=st.integers(10, 300),
    d=st.integers(2, 5),  # bits=6 per dim: d=6 would exceed the 32-bit key
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_rank_stats_order_invariant_to_monotone_transform(n, d, seed):
    """Property: rank-space keys are invariant under per-dim monotone maps
    (the 'statistics' mode really uses the distribution, not geometry)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, d)).astype(np.float32)
    # a monotone, nonlinear transform that keeps float32 values distinct
    warped = np.exp(2.0 * pts.astype(np.float64)).astype(np.float32)
    if any(len(np.unique(warped[:, j])) != n for j in range(d)):
        return  # float32 tie after warping: rank order undefined, skip
    k1 = np.asarray(sfc.hilbert_key(jnp.asarray(pts), 6, stats="rank"))
    k2 = np.asarray(sfc.hilbert_key(jnp.asarray(warped), 6, stats="rank"))
    assert (k1 == k2).all()


def test_words2_refines_words1(rng):
    pts = jnp.asarray(rng.random((512, 3)), jnp.float32)
    k1 = np.asarray(sfc.morton_key(pts, 10, words=1)).astype(np.int64)
    k2 = sfc.morton_key(pts, 20, words=2)
    o2 = np.asarray(sfc.argsort_keys(k2))
    # sorting by the refined key must also sort the coarse key
    assert (np.diff(k1[o2]) >= 0).all() or True  # coarse ties can reorder
    coarse_sorted = k1[o2]
    assert (np.diff(coarse_sorted) >= 0).all()


def test_shared_frame_helpers_are_the_one_convention(rng):
    """sfc.keys_in_frame is THE frozen-frame keying; the curve_index
    re-export, the kernels.ops cache path and point_key_morton3d must
    all produce identical keys for identical (frame, bits, curve)."""
    from repro.core import curve_index as ci
    from repro.kernels import ops as kops

    pts = jnp.asarray(rng.random((512, 3)), jnp.float32)
    lo = jnp.asarray([-0.2, -0.2, -0.2], jnp.float32)
    hi = jnp.asarray([1.3, 1.3, 1.3], jnp.float32)
    k_sfc = np.asarray(sfc.keys_in_frame(pts, lo, hi, bits=10, curve="morton"))
    k_ci = np.asarray(ci.keys_in_frame(pts, lo, hi, bits=10, curve="morton"))
    k_pk = np.asarray(sfc.point_key_morton3d(pts, lo, hi, bits=10))
    kops.invalidate_key_cache()
    k_ops = np.asarray(
        kops.cached_sfc_key(pts, token=9999, curve="morton", bits=10, lo=lo, hi=hi)
    )
    kops.invalidate_key_cache(9999)
    np.testing.assert_array_equal(k_sfc, k_ci)
    np.testing.assert_array_equal(k_sfc, k_pk)
    np.testing.assert_array_equal(k_sfc, k_ops)
    # in-frame keys agree with the data-fitted quantization when the
    # frame IS the data bbox
    dlo, dhi = sfc.bbox_frame(pts)
    np.testing.assert_array_equal(
        np.asarray(sfc.keys_in_frame(pts, dlo, dhi, bits=8, curve="hilbert")),
        np.asarray(
            sfc.hilbert_key_from_cells(sfc.cells_in_frame(pts, dlo, dhi, 8), 8)
        ),
    )
