"""Per-arch smoke tests: every assigned architecture instantiates at a
REDUCED config and runs one forward/train step + one decode step on CPU,
asserting shapes and finiteness (harness deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import RunConfig, SHAPES
from repro.models import model as M
from repro.optim import adamw
from repro.train import step as ts

ARCH_IDS = sorted(ARCHS)

# tier-1 runs dense + MoE representatives (SSM forward/decode is covered
# by the decode-consistency oracle below); the rest run under `-m slow`
FAST_ARCHS = {"smollm-135m", "qwen3-moe-30b-a3b"}
ARCH_PARAMS = [
    a if a in FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow) for a in ARCH_IDS
]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_train_step(arch, key):
    cfg = reduced(ARCHS[arch])
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"])
    params, opt_state = ts.init_all(run, key)
    batch = M.synthetic_batch(cfg, 2, 32, key)
    step = jax.jit(ts.make_train_step(run, total_steps=100))
    params2, opt2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(opt2["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(
            lambda x, y: float(jnp.sum(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)))),
            params, params2,
        ),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_decode_step(arch, key):
    cfg = reduced(ARCHS[arch])
    mdl = M.get_model(cfg)
    params = mdl.init_params(cfg, key)
    cache = mdl.init_cache(cfg, 2, 64)
    fn = jax.jit(M.serve_step_fn(cfg))
    out = fn(params, {
        "token": jnp.array([1, 2], jnp.int32),
        "pos": jnp.zeros(2, jnp.int32),
        "cache": cache,
    })
    assert out["logits"].shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(out["logits"]).all())


LOSS_FAST = {"smollm-135m", "qwen3-moe-30b-a3b"}
LOSS_PARAMS = [
    a if a in LOSS_FAST else pytest.param(a, marks=pytest.mark.slow) for a in ARCH_IDS
]


@pytest.mark.parametrize("arch", LOSS_PARAMS)
def test_loss_decreases(arch, key):
    """3 steps on a repeated batch must reduce loss (learning sanity)."""
    cfg = reduced(ARCHS[arch])
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], learning_rate=1e-2, warmup_steps=1)
    params, opt_state = ts.init_all(run, key)
    batch = M.synthetic_batch(cfg, 2, 32, key)
    step = jax.jit(ts.make_train_step(run, total_steps=100))
    losses = []
    for _ in range(4):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.slow
def test_decode_matches_forward_dense(key):
    """Causal consistency: token-by-token decode logits == full forward
    logits for the dense family (KV-cache correctness oracle)."""
    cfg = reduced(ARCHS["smollm-135m"])
    mdl = M.get_model(cfg)
    params = mdl.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = mdl.forward(params, toks, cfg)
    cache = mdl.init_cache(cfg, 2, 16)
    errs = []
    for t in range(8):
        logits, cache = mdl.decode_step(params, cache, toks[:, t], jnp.full((2,), t, jnp.int32), cfg)
        errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    assert max(errs) < 2e-1, errs  # bf16 accumulation tolerance


def test_decode_matches_forward_ssm(key):
    cfg = reduced(ARCHS["mamba2-130m"], ssm_chunk=4)
    mdl = M.get_model(cfg)
    params = mdl.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = mdl.forward(params, toks, cfg)
    cache = mdl.init_cache(cfg, 2, 16)
    errs = []
    for t in range(8):
        logits, cache = mdl.decode_step(params, cache, toks[:, t], jnp.full((2,), t, jnp.int32), cfg)
        errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    assert max(errs) < 2e-1, errs


def test_blockwise_attention_matches_naive(key):
    from repro.models import layers as L

    B, S, H, KV, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    for window in (0, 16):
        bias = L._mask_bias(pos, pos, causal=True, window=window)
        naive = L._sdpa(q, k, v, bias)
        blocked = L._sdpa_blockwise(
            q, k, v, causal=True, window=window, prefix_len=0, block_q=16, block_k=16
        )
        err = float(jnp.max(jnp.abs(naive - blocked)))
        assert err < 1e-4, f"window={window}: {err}"


@pytest.mark.slow
def test_sliding_window_decode_rolls(key):
    """Rolling KV buffer: decode far beyond the window stays finite and
    attends only within the window."""
    cfg = reduced(ARCHS["mixtral-8x22b"])  # window=16
    mdl = M.get_model(cfg)
    params = mdl.init_params(cfg, key)
    cache = mdl.init_cache(cfg, 1, 64)
    assert cache["k"].shape[2] == cfg.window  # rolling buffer capped
    tok = jnp.array([3], jnp.int32)
    for t in range(40):  # > 2x window
        logits, cache = mdl.decode_step(params, cache, tok, jnp.array([t], jnp.int32), cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())


def test_moe_routing_uses_capacity(key):
    from repro.models import moe as Mo

    cfg = reduced(ARCHS["qwen3-moe-30b-a3b"])
    p = Mo.moe_init(key, cfg, jnp.bfloat16)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.bfloat16)
    y, aux = Mo.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound
    load = Mo.expert_load(p, x.astype(jnp.float32), cfg)
    assert int(load.sum()) == 2 * 32 * cfg.num_experts_per_tok
