"""Hierarchical (node -> device) partition core.

Local tests cover the nested knapsack and the two-level engine; the
distributed equivalence and the two-level serving path run in a
subprocess with 8 fake host devices (see test_distributed.py for why).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import knapsack, migration, partitioner
from repro.core.repartition import HierarchicalRepartitioner

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}"
        " --xla_backend_optimization_level=0"
    )
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


# ---------------------------------------------------------------------------
# nested knapsack
# ---------------------------------------------------------------------------

def test_two_level_slice_trivial_top_is_bit_identical(rng):
    """nodes=1 must reduce bit-exactly to the flat knapsack — the flat
    path IS the trivial hierarchy, so the reduction cannot be 'close'."""
    w = jnp.asarray((0.1 + rng.random(20_000)).astype(np.float32))
    for parts in (1, 7, 64):
        node, dev, part = knapsack.two_level_slice(w, 1, parts)
        np.testing.assert_array_equal(
            np.asarray(part), np.asarray(knapsack.slice_weighted_curve(w, parts))
        )
        assert int(np.asarray(node).max()) == 0


def test_two_level_slice_nested_balance_bounds(rng):
    """Both levels obey the paper's knapsack guarantee at their own
    granularity: node spread and per-node device spread are each bounded
    by ~2x the max element weight."""
    w_h = (0.1 + rng.random(16_384)).astype(np.float32)
    node, dev, part = knapsack.two_level_slice(jnp.asarray(w_h), 4, 4)
    nh, ph = np.asarray(node), np.asarray(part)
    assert (np.diff(nh) >= 0).all() and (np.diff(ph) >= 0).all()
    np.testing.assert_array_equal(ph, nh * 4 + np.asarray(dev))
    nl = np.zeros(4)
    np.add.at(nl, nh, w_h)
    assert nl.max() - nl.min() <= 2 * w_h.max() + 1e-3
    pl = np.zeros(16)
    np.add.at(pl, ph, w_h)
    for j in range(4):
        d = pl[4 * j : 4 * (j + 1)]
        assert d.max() - d.min() <= 2 * w_h.max() + 1e-3


def test_device_slice_within_frozen_nodes_rebalances_locally(rng):
    """The intra-node level: node assignment frozen, drifted weights —
    devices rebalance within each node and no element changes node."""
    w0 = (0.5 + rng.random(8_192)).astype(np.float32)
    node, _, _ = knapsack.two_level_slice(jnp.asarray(w0), 2, 4)
    w1 = w0 * (1 + 4 * (np.arange(8_192) % 9 == 0)).astype(np.float32)
    dev = knapsack.device_slice_within_nodes(jnp.asarray(w1), node, 2, 4)
    part = np.asarray(node) * 4 + np.asarray(dev)
    pl = np.zeros(8)
    np.add.at(pl, part, w1)
    for j in range(2):
        d = pl[4 * j : 4 * (j + 1)]
        assert d.max() - d.min() <= 2 * w1.max() + 1e-3


# ---------------------------------------------------------------------------
# local hierarchical partition / reslice
# ---------------------------------------------------------------------------

def test_hierarchical_partition_trivial_top_matches_flat_tree_path(rng):
    """Acceptance: a (1, D) hierarchy is bit-identical to the flat
    partition on both substrates — part, boundaries and loads."""
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    w = jnp.asarray((0.5 + rng.random(4096)).astype(np.float32))
    for cfg in (
        partitioner.PartitionerConfig(use_tree=True, max_depth=8),
        partitioner.PartitionerConfig(),
    ):
        flat = partitioner.partition(pts, w, 8, cfg)
        hier = partitioner.hierarchical_partition(
            pts, w, partitioner.HierarchyPlan(1, 8), cfg
        )
        np.testing.assert_array_equal(np.asarray(flat.part), np.asarray(hier.part))
        np.testing.assert_array_equal(
            np.asarray(flat.boundaries), np.asarray(hier.boundaries)
        )
        np.testing.assert_array_equal(np.asarray(flat.loads), np.asarray(hier.loads))


def test_hierarchical_partition_two_level_invariants(rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    w_h = (0.5 + rng.random(4096)).astype(np.float32)
    plan = partitioner.HierarchyPlan(2, 4)
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=8)
    res = partitioner.hierarchical_partition(pts, jnp.asarray(w_h), plan, cfg)
    part, node = np.asarray(res.part), np.asarray(res.node)
    # the two levels are consistent everywhere
    np.testing.assert_array_equal(node, part // 4)
    np.testing.assert_array_equal(node, plan.node_of_part(part))
    # loads are exact per level and nest
    oracle = np.zeros(8)
    np.add.at(oracle, part, w_h)
    np.testing.assert_allclose(np.asarray(res.loads), oracle, rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(res.loads).reshape(2, 4).sum(1), np.asarray(res.node_loads),
        rtol=1e-4,
    )
    # node balance at bucket granularity
    maxbw = float(np.asarray(res.summary.weight).max())
    nl = np.asarray(res.node_loads)
    assert nl.max() - nl.min() <= 2 * maxbw + 1e-3
    # boundaries cover the curve at both levels
    assert np.asarray(res.node_boundaries)[0] == 0
    assert np.asarray(res.node_boundaries)[-1] == 4096
    # every 4th part boundary IS a node boundary (slices nest)
    np.testing.assert_array_equal(
        np.asarray(res.boundaries)[::4], np.asarray(res.node_boundaries)
    )


def test_hierarchical_reslice_intra_keeps_nodes(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w0 = (0.5 + rng.random(2048)).astype(np.float32)
    plan = partitioner.HierarchyPlan(2, 4)
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=8)
    res = partitioner.hierarchical_partition(pts, jnp.asarray(w0), plan, cfg)
    w1 = w0 * (1 + 3 * (np.arange(2048) % 7 == 0)).astype(np.float32)
    r_intra = partitioner.hierarchical_reslice(res, jnp.asarray(w1), level="intra")
    # frozen node level: zero cross-node movement by construction
    np.testing.assert_array_equal(np.asarray(r_intra.node), np.asarray(res.node))
    oracle = np.zeros(8)
    np.add.at(oracle, np.asarray(r_intra.part), w1)
    np.testing.assert_allclose(np.asarray(r_intra.loads), oracle, rtol=1e-4)
    # full reslice on the cached order == fresh partition (midpoint
    # splitters ignore weights, so the tree is identical)
    r_full = partitioner.hierarchical_reslice(res, jnp.asarray(w1), level="full")
    fresh = partitioner.hierarchical_partition(pts, jnp.asarray(w1), plan, cfg)
    np.testing.assert_array_equal(np.asarray(r_full.part), np.asarray(fresh.part))


# ---------------------------------------------------------------------------
# hierarchical incremental engine (two-level Alg. 3 trigger)
# ---------------------------------------------------------------------------

def test_hierarchical_engine_small_drift_fires_intra(rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    w = (0.5 + rng.random(4096)).astype(np.float32)
    plan = partitioner.HierarchyPlan(2, 4, inter_node_cost=4.0)
    rp = HierarchicalRepartitioner(
        pts, jnp.asarray(w), plan, max_depth=8, capacity=4096
    )
    rp.update_weights(jnp.asarray(w * (1 + 0.05 * rng.random(4096)).astype(np.float32)))
    step = rp.rebalance()
    assert step.level == "intra"
    assert rp.stats.intra_reslices == 1 and rp.stats.inter_reslices == 0
    # an intra step's migration plan has zero inter-node movement and a
    # node-level stay fraction of exactly 1
    assert isinstance(step.plan, migration.HierarchicalMigrationPlan)
    assert step.plan.inter_moved == 0
    assert step.plan.stay_fraction_node == 1.0
    assert step.node_loads.shape == (2,)


def test_hierarchical_engine_node_skew_fires_inter(rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    w = (0.5 + rng.random(4096)).astype(np.float32)
    plan = partitioner.HierarchyPlan(2, 4)
    rp = HierarchicalRepartitioner(
        pts, jnp.asarray(w), plan, max_depth=8, capacity=4096
    )
    # node-skewed drift: 5x the weight of everything on node 0
    node_pp = np.asarray(rp.node_part)
    w2 = w * np.where(node_pp == 0, 5.0, 1.0).astype(np.float32)
    rp.update_weights(jnp.asarray(w2))
    assert rp.node_imbalance() > rp.node_threshold
    step = rp.rebalance()
    assert step.level == "inter"
    assert rp.stats.inter_reslices == 1
    # the inter-node re-slice actually fixed the node imbalance
    assert step.node_imbalance < 1.05
    assert step.plan.inter_moved > 0
    assert step.plan.stay_fraction_node < 1.0
    # element conservation through the count matrix (stable slots only)
    assert step.plan.send_counts.sum() == 4096


def test_hierarchical_engine_step_and_deltas(rng):
    """step() keeps Alg. 3 semantics; insert/delete ride the bucket
    substrate unchanged."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.asarray((0.5 + rng.random(2048)).astype(np.float32))
    rp = HierarchicalRepartitioner(
        pts, w, partitioner.HierarchyPlan(2, 2), max_depth=8, capacity=2048 + 128
    )
    s = rp.step()
    assert s.kind in ("incremental", "rebuild")
    slots = rp.insert(
        jnp.asarray(rng.random((64, 3)), jnp.float32), jnp.ones(64, jnp.float32)
    )
    rp.delete(slots[:32])
    s2 = rp.rebalance()
    part = np.asarray(s2.part)
    assert (part[np.asarray(rp.dps.active)] >= 0).all()
    assert rp.num_active() == 2048 + 32
    # the engine never generated a per-point key
    assert rp.stats.keygen_points == 0


def test_parse_inter_node_bytes_classifies_replica_groups():
    """The bench gate's measurement: collective traffic split by node
    from replica groups (pure HLO-text parsing, no devices needed)."""
    from repro.launch import dryrun

    hlo = """
  %all-gather.1 = f32[4,16]{1,0} all-gather(f32[1,16]{1,0} %x), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %all-gather.2 = f32[2,16]{1,0} all-gather(f32[1,16]{1,0} %y), channel_id=2, replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
"""
    out = dryrun.parse_inter_node_bytes(hlo, [g // 4 for g in range(8)])
    # gather 1 (intra-node groups): per-peer 64 B, 4 members x 3 peers
    # x 2 groups; gather 2 (node-pair groups): 8 members x 1 cross peer
    assert out["intra_node_bytes"] == 2 * 4 * 3 * 64
    assert out["inter_node_bytes"] == 8 * 64
    assert out["collectives"] == 2 and out["unparsed"] == 0


# ---------------------------------------------------------------------------
# distributed equivalence + two-level serving (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def test_distributed_hierarchy_trivial_top_equals_flat_and_two_level_balances():
    """Acceptance: `hierarchical_bucket_partition` on a (1, D) mesh is
    bit-identical to the flat `distributed_bucket_partition` (a true 2-D
    mesh vs a 1-D mesh — different shard_map topologies, same math), and
    on a (2, 4) mesh the two-level path conserves mass, balances at
    bucket granularity, and its cached-tree reslice equals a fresh
    partition on drifted weights."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import partitioner as pt
        from repro.core.repartition import DistributedBucketRepartitioner
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        rng = np.random.default_rng(0)
        n, PARTS = 4096, 8
        pts_h = rng.random((n,3)).astype(np.float32)
        pts_h[: n // 2] = 0.45 + 0.1 * pts_h[: n // 2]
        wts_h = (0.1 + rng.random(n)).astype(np.float32)
        cfg = pt.PartitionerConfig(use_tree=True, max_depth=8, bucket_size=16)

        mesh_f = make_mesh((8,), ("data",))
        sh_f = NamedSharding(mesh_f, P("data"))
        part_f, leaf_f, keys_f = pt.distributed_bucket_partition(
            mesh_f, "data", jax.device_put(jnp.asarray(pts_h), sh_f),
            jax.device_put(jnp.asarray(wts_h), sh_f), PARTS, cfg=cfg)

        mesh_18 = shd.make_node_device_mesh(1, 8)
        sh_18 = NamedSharding(mesh_18, P(("node", "device")))
        part_h, leaf_h, keys_h = pt.hierarchical_bucket_partition(
            mesh_18, pt.HierarchyPlan(1, PARTS),
            jax.device_put(jnp.asarray(pts_h), sh_18),
            jax.device_put(jnp.asarray(wts_h), sh_18), cfg=cfg)
        np.testing.assert_array_equal(np.asarray(part_f), np.asarray(part_h))
        np.testing.assert_array_equal(np.asarray(leaf_f), np.asarray(leaf_h))
        np.testing.assert_array_equal(np.asarray(keys_f), np.asarray(keys_h))

        mesh_24 = shd.make_node_device_mesh(2, 4)
        plan = pt.HierarchyPlan(2, 4)
        sh_24 = NamedSharding(mesh_24, P(("node", "device")))
        pts_d = jax.device_put(jnp.asarray(pts_h), sh_24)
        wts_d = jax.device_put(jnp.asarray(wts_h), sh_24)
        eng = DistributedBucketRepartitioner(mesh_24, cfg=cfg, plan=plan)
        part = eng.partition(pts_d, wts_d)
        p = np.asarray(part)
        assert p.shape[0] == n and (p >= 0).all() and (p < PARTS).all()
        loads = np.zeros(PARTS); np.add.at(loads, p, wts_h)
        np.testing.assert_allclose(loads.sum(), wts_h.sum(), rtol=1e-5)
        # node loads balance within the aggregated-bin granularity: a bin
        # merges up to S_d raw records, so the bound scales accordingly
        lid = np.asarray(eng.leaf_id).reshape(8, -1)
        wsh = wts_h.reshape(8, -1)
        maxbw = 0.0
        for s in range(8):
            bw = np.zeros(lid[s].max() + 1); np.add.at(bw, lid[s], wsh[s])
            maxbw = max(maxbw, bw.max())
        nl = loads.reshape(2, 4).sum(1)
        assert nl.max() - nl.min() <= 2 * 4 * maxbw + 1e-3, (nl, maxbw)
        # device level slices the same aggregated bins: within every
        # node, device spread is bounded at bin granularity too
        for j in range(2):
            dl = loads[4 * j : 4 * (j + 1)]
            assert dl.max() - dl.min() <= 2 * 4 * maxbw + 1e-3, (dl, maxbw)
        # regression: summary_bins that does NOT divide the stage-1
        # record count (bin boundary key = ceil, not floor) — the
        # partition must stay a valid conserving assignment
        plan_nb = pt.HierarchyPlan(2, 4, summary_bins=48)
        p_nb = np.asarray(pt.hierarchical_bucket_partition(
            mesh_24, plan_nb, pts_d, wts_d, cfg=cfg)[0])
        assert (p_nb >= 0).all() and (p_nb < PARTS).all()
        loads_nb = np.zeros(PARTS); np.add.at(loads_nb, p_nb, wts_h)
        np.testing.assert_allclose(loads_nb.sum(), wts_h.sum(), rtol=1e-5)
        # reslice on cached trees == fresh partition on drifted weights
        w2_h = wts_h * (1.0 + 2.0 * (np.arange(n) % 5 == 0)).astype(np.float32)
        w2 = jax.device_put(jnp.asarray(w2_h), sh_24)
        p_re = np.asarray(eng.rebalance(w2))
        p_fresh = np.asarray(pt.hierarchical_bucket_partition(
            mesh_24, plan, pts_d, w2, cfg=cfg)[0])
        np.testing.assert_array_equal(p_re, p_fresh)
        assert eng.reslices == 1 and eng.full_partitions == 1
        # level-aware migration accounting from the engine
        mplan = eng.migration_between(p, p_re)
        assert mplan.intra_moved + mplan.inter_moved + np.trace(mplan.send_counts) == n
        # the byte accounting the bench gates on
        m = np.asarray(eng.node_keys).shape[0] // 8
        acct = shd.summary_exchange_bytes(plan, m)
        assert acct["two_level_inter_node_bytes"] < acct["flat_inter_node_bytes"]
        print("OK")
    """)
    assert "OK" in out


def test_two_level_serving_matches_flat_routing():
    """DistributedQueryEngine on a (node, device) mesh: the hierarchical
    key -> node -> device routing answers exactly like flat routing and
    like the local oracle."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import partitioner as pt
        from repro.core.repartition import Repartitioner
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.serve.query_engine import DistributedQueryEngine
        rng = np.random.default_rng(0)
        n, Q = 4096, 512
        pts = jnp.asarray(rng.random((n,3)), jnp.float32)
        wts = jnp.asarray(0.5 + rng.random(n), jnp.float32)
        rp = Repartitioner(pts, wts, 16, pt.PartitionerConfig(curve="morton"),
                           max_depth=10, capacity=n)
        q_hit = pts[jnp.asarray(rng.choice(n, Q, replace=True))]
        q_rand = jnp.asarray(rng.random((Q,3)), jnp.float32)
        eng2 = DistributedQueryEngine(
            rp.curve_index(), shd.make_node_device_mesh(2, 4), ("node", "device"))
        eng1 = DistributedQueryEngine(
            rp.curve_index(), make_mesh((8,), ("data",)), "data")
        eng0 = DistributedQueryEngine(rp.curve_index())
        f2, i2, ok2 = eng2.point_location(q_hit)
        f1, i1, ok1 = eng1.point_location(q_hit)
        f0, i0, ok0 = eng0.point_location(q_hit)
        np.testing.assert_array_equal(np.asarray(f2), np.asarray(f1))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(i1))
        np.testing.assert_array_equal(np.asarray(ok2), np.asarray(ok1))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(i0))
        assert np.asarray(f2).all()
        d2, g2 = eng2.knn(q_rand, 3)
        d1, g1 = eng1.knn(q_rand, 3)
        np.testing.assert_array_equal(np.asarray(g2), np.asarray(g1))
        np.testing.assert_allclose(np.asarray(d2), np.asarray(d1), rtol=1e-6)
        # live refresh in two-level mode
        rp.rebuild()
        assert eng2.maybe_refresh(rp)
        f3, i3, _ = eng2.point_location(q_hit)
        np.testing.assert_array_equal(np.asarray(i3), np.asarray(i0))
        print("OK")
    """)
    assert "OK" in out
