"""End-to-end partitioner: quality metrics + distributed path (multi-device
subprocess covered in test_distributed.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, partitioner


def test_partition_balances_weighted_points(rng):
    pts = jnp.asarray(rng.random((8192, 3)), jnp.float32)
    w = jnp.asarray((rng.random(8192) + 0.5).astype(np.float32))
    res = partitioner.partition(pts, w, num_parts=12)
    loads = np.asarray(res.loads)
    assert loads.max() - loads.min() <= 2 * float(w.max()) + 1e-3
    # part is a valid assignment of every original element
    assert np.asarray(res.part).min() >= 0 and np.asarray(res.part).max() == 11


@pytest.mark.parametrize("curve", ["morton", "hilbert"])
def test_partitions_are_spatially_compact(curve, rng):
    pts = jnp.asarray(rng.random((4096, 2)), jnp.float32)
    cfg = partitioner.PartitionerConfig(curve=curve)
    res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
    frac = metrics.knn_cross_fraction(np.asarray(pts), np.asarray(res.part), k=4, sample=512)
    # random assignment would cross ~ 7/8 = 0.875 of kNN edges
    assert frac < 0.25, f"{curve} partition not compact: {frac}"


def test_hilbert_cut_leq_morton(rng):
    pts = jnp.asarray(rng.random((8192, 2)), jnp.float32)
    fracs = {}
    for curve in ("morton", "hilbert"):
        cfg = partitioner.PartitionerConfig(curve=curve)
        res = partitioner.partition(pts, None, num_parts=16, cfg=cfg)
        fracs[curve] = metrics.knn_cross_fraction(
            np.asarray(pts), np.asarray(res.part), k=4, sample=1024
        )
    assert fracs["hilbert"] <= fracs["morton"] * 1.1  # allow small noise


@pytest.mark.slow  # full tree-order pipeline: heaviest compile in the module
def test_tree_pipeline_matches_quality(rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=10)
    res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
    loads = np.asarray(res.loads)
    assert loads.max() - loads.min() <= 2.0 + 1e-3
    frac = metrics.knn_cross_fraction(np.asarray(pts), np.asarray(res.part), k=4, sample=512)
    assert frac < 0.3


def test_pallas_path_matches_jnp(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.ones(2048, jnp.float32)
    a = partitioner.partition(pts, w, 8, partitioner.PartitionerConfig(use_pallas=False))
    b = partitioner.partition(pts, w, 8, partitioner.PartitionerConfig(use_pallas=True))
    assert (np.asarray(a.part) == np.asarray(b.part)).all()


@pytest.mark.slow
def test_rank_stats_improves_clustered_balance(rng):
    """Clustered data: rank quantization (median-splitter equivalent)
    fills key space evenly -> finer effective resolution."""
    clu = np.concatenate(
        [rng.normal(0.02, 0.002, (6000, 3)), rng.random((2000, 3))]
    ).astype(np.float32)
    pts = jnp.asarray(clu)
    for stats in ("geometric", "rank"):
        cfg = partitioner.PartitionerConfig(stats=stats, bits=4)
        res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
        loads = np.asarray(res.loads)
        if stats == "geometric":
            geo_spread = loads.max() - loads.min()
        else:
            rank_spread = loads.max() - loads.min()
    # at coarse bit budgets, geometric keys collapse the dense cluster into
    # few cells (ties break balance); rank keys cannot collapse
    assert rank_spread <= geo_spread
