"""End-to-end partitioner: quality metrics + distributed path (multi-device
subprocess covered in test_distributed.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics, partitioner


def test_partition_balances_weighted_points(rng):
    pts = jnp.asarray(rng.random((8192, 3)), jnp.float32)
    w = jnp.asarray((rng.random(8192) + 0.5).astype(np.float32))
    res = partitioner.partition(pts, w, num_parts=12)
    loads = np.asarray(res.loads)
    assert loads.max() - loads.min() <= 2 * float(w.max()) + 1e-3
    # part is a valid assignment of every original element
    assert np.asarray(res.part).min() >= 0 and np.asarray(res.part).max() == 11


@pytest.mark.parametrize("curve", ["morton", "hilbert"])
def test_partitions_are_spatially_compact(curve, rng):
    pts = jnp.asarray(rng.random((4096, 2)), jnp.float32)
    cfg = partitioner.PartitionerConfig(curve=curve)
    res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
    frac = metrics.knn_cross_fraction(np.asarray(pts), np.asarray(res.part), k=4, sample=512)
    # random assignment would cross ~ 7/8 = 0.875 of kNN edges
    assert frac < 0.25, f"{curve} partition not compact: {frac}"


def test_hilbert_cut_leq_morton(rng):
    pts = jnp.asarray(rng.random((8192, 2)), jnp.float32)
    fracs = {}
    for curve in ("morton", "hilbert"):
        cfg = partitioner.PartitionerConfig(curve=curve)
        res = partitioner.partition(pts, None, num_parts=16, cfg=cfg)
        fracs[curve] = metrics.knn_cross_fraction(
            np.asarray(pts), np.asarray(res.part), k=4, sample=1024
        )
    assert fracs["hilbert"] <= fracs["morton"] * 1.1  # allow small noise


@pytest.mark.slow  # full tree pipeline: heaviest compile in the module
def test_tree_pipeline_matches_quality(rng):
    pts = jnp.asarray(rng.random((4096, 3)), jnp.float32)
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=10)
    res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
    loads = np.asarray(res.loads)
    # balance granularity on the tree path is one *bucket*
    max_bucket = float(np.asarray(res.summary.weight).max())
    assert loads.max() - loads.min() <= 2 * max_bucket + 1e-3
    frac = metrics.knn_cross_fraction(np.asarray(pts), np.asarray(res.part), k=4, sample=512)
    assert frac < 0.3


def test_tree_partition_no_point_sort_contract(rng):
    """The bucket pipeline: part/keys/boundaries come from O(B) summaries
    + gathers; res.perm is None because no per-point sort ran, and
    materialize_perm pays it explicitly."""
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.asarray((0.5 + rng.random(2048)).astype(np.float32))
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=8)
    res = partitioner.partition(pts, w, num_parts=8, cfg=cfg)
    assert res.perm is None and res.summary is not None
    part = np.asarray(res.part)
    assert part.min() >= 0 and part.max() == 7
    # loads are exact point-weight sums (bucket weights aggregate them)
    oracle = np.zeros(8)
    np.add.at(oracle, part, np.asarray(w))
    np.testing.assert_allclose(np.asarray(res.loads), oracle, rtol=1e-4)
    # boundaries slice the bucket-major order into the same part sizes
    np.testing.assert_array_equal(
        np.diff(np.asarray(res.boundaries)), np.bincount(part, minlength=8)
    )
    # every bucket maps to exactly one part (points follow their bucket)
    leaf = np.asarray(res.tree.leaf_id)
    bp = np.asarray(res.bucket_part)
    assert (part == bp[leaf]).all()
    perm = np.asarray(partitioner.materialize_perm(res))
    assert len(np.unique(perm)) == 2048
    assert (np.diff(np.asarray(res.bucket_rank)[perm]) >= 0).all()


def test_tree_and_point_paths_agree_on_balance_bounds(rng):
    """Property: both substrates respect their own knapsack guarantee —
    spread <= 2x their balance granularity (element weight for the point
    path, bucket weight for the tree path) — and produce spatially
    compact parts on the same inputs."""
    for seed in (0, 1, 2):
        r = np.random.default_rng(seed)
        n = 1024 + 512 * seed
        pts = jnp.asarray(r.random((n, 2)), jnp.float32)
        w = jnp.asarray((0.5 + r.random(n)).astype(np.float32))
        res_pt = partitioner.partition(pts, w, 8, partitioner.PartitionerConfig())
        res_tr = partitioner.partition(
            pts, w, 8, partitioner.PartitionerConfig(use_tree=True, max_depth=8)
        )
        l_pt, l_tr = np.asarray(res_pt.loads), np.asarray(res_tr.loads)
        assert l_pt.max() - l_pt.min() <= 2 * float(np.asarray(w).max()) + 1e-3
        assert l_tr.max() - l_tr.min() <= 2 * float(
            np.asarray(res_tr.summary.weight).max()
        ) + 1e-3
        # same total mass either way
        np.testing.assert_allclose(l_pt.sum(), l_tr.sum(), rtol=1e-5)
        for res in (res_pt, res_tr):
            frac = metrics.knn_cross_fraction(
                np.asarray(pts), np.asarray(res.part), k=4, sample=256
            )
            assert frac < 0.35, frac


def test_partition_with_index_accepts_tree_path(rng):
    """partition_with_index(use_tree=True): the tree-backed index answers
    exact point location for stored points, with the directory equal to
    the tree's buckets — one (bucket) key generation."""
    from repro.core import queries

    pts = jnp.asarray(rng.random((1024, 3)), jnp.float32)
    cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=8)
    res, idx = partitioner.partition_with_index(pts, None, 4, cfg)
    assert idx.tree is not None
    assert idx.num_buckets == int(res.bucket_order.num_buckets)
    q = pts[jnp.asarray(rng.choice(1024, 256, replace=False))]
    found, ids, ok = queries.point_location(idx, q, bucket_cap=128)
    assert bool(np.asarray(found).all()) and bool(np.asarray(ok).all())
    # recovered ids point at coordinate-identical rows
    np.testing.assert_array_equal(
        np.asarray(pts)[np.asarray(ids)], np.asarray(q)
    )
    d, g = queries.knn(idx, q[:64], k=2)
    assert float(np.asarray(d)[:, 0].max()) == 0.0  # self is nearest
    # off-data queries miss (tree walk still lands in a real bucket)
    qoff = jnp.asarray(rng.random((32, 3)).astype(np.float32) + 2.0)
    f2, _, ok2 = queries.point_location(idx, qoff, bucket_cap=128)
    assert not bool(np.asarray(f2).any())


def test_tree_path_bucket_in_last_curve_cell_not_dropped(rng):
    """Regression: at full key width (bits*d == 32) a bucket whose
    centroid lands in the LAST curve cell used to key to the sentinel
    and vanish behind the non-bucket tail — its points invisible to the
    directory and mis-assigned to the last part."""
    from repro.core import queries

    n = 500
    pts_h = rng.random((n, 2)).astype(np.float32)
    pts_h[-40:] = [0.999, 0.999]  # a dense bucket at the bbox-max corner
    pts = jnp.asarray(pts_h)
    cfg = partitioner.PartitionerConfig(curve="morton", use_tree=True, max_depth=8)
    res, idx = partitioner.partition_with_index(pts, None, 4, cfg)
    # every point is inside the directory's coverage
    assert int(np.asarray(idx.bucket_starts)[-1]) == n
    assert int(np.asarray(res.bucket_order.starts)[int(res.bucket_order.num_buckets)]) == n
    # the corner points are found exactly, and kNN sees them
    q = jnp.asarray(np.array([[0.999, 0.999]], np.float32))
    found, ids, ok = queries.point_location(idx, q, bucket_cap=128)
    assert bool(np.asarray(found)[0])
    d, g = queries.knn(idx, q, k=3)
    assert float(np.asarray(d)[0, 0]) == 0.0


def test_pallas_path_matches_jnp(rng):
    pts = jnp.asarray(rng.random((2048, 3)), jnp.float32)
    w = jnp.ones(2048, jnp.float32)
    a = partitioner.partition(pts, w, 8, partitioner.PartitionerConfig(use_pallas=False))
    b = partitioner.partition(pts, w, 8, partitioner.PartitionerConfig(use_pallas=True))
    assert (np.asarray(a.part) == np.asarray(b.part)).all()


@pytest.mark.slow
def test_rank_stats_improves_clustered_balance(rng):
    """Clustered data: rank quantization (median-splitter equivalent)
    fills key space evenly -> finer effective resolution."""
    clu = np.concatenate(
        [rng.normal(0.02, 0.002, (6000, 3)), rng.random((2000, 3))]
    ).astype(np.float32)
    pts = jnp.asarray(clu)
    for stats in ("geometric", "rank"):
        cfg = partitioner.PartitionerConfig(stats=stats, bits=4)
        res = partitioner.partition(pts, None, num_parts=8, cfg=cfg)
        loads = np.asarray(res.loads)
        if stats == "geometric":
            geo_spread = loads.max() - loads.min()
        else:
            rank_spread = loads.max() - loads.min()
    # at coarse bit budgets, geometric keys collapse the dense cluster into
    # few cells (ties break balance); rank keys cannot collapse
    assert rank_spread <= geo_spread
