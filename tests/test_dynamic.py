"""Dynamic trees (Alg. 1) + amortized load balancing (Alg. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import dynamic


def _mk(rng, n=1024, depth=8, b=32):
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)
    return dynamic.from_points(pts, max_depth=depth, bucket_size=b)


def _conserved(dps) -> bool:
    M = dps.tree.num_nodes
    holds = jax.ops.segment_sum(dps.active.astype(jnp.int32), dps.leaf_id, num_segments=M)
    return int(holds.sum()) == int(dps.active.sum()) and int(dps.tree.count[0]) == int(
        dps.active.sum()
    )


@pytest.mark.slow  # covered at smaller scale by the adjustment property test
def test_insert_locates_and_counts(rng):
    dps = _mk(rng)
    new = jnp.asarray(rng.random((500, 3)), jnp.float32)
    dps2 = dynamic.insert(dps, new, jnp.ones(500, jnp.float32))
    assert int(dps2.active.sum()) == 1524
    assert int(dps2.tree.count[0]) == 1524  # root count bumped along paths


def test_delete_decrements(rng):
    dps = _mk(rng)
    dps2 = dynamic.delete(dps, jnp.arange(100))
    assert int(dps2.active.sum()) == 924
    assert int(dps2.tree.count[0]) == 924


@pytest.mark.slow  # depth-20 build: ~30 s of XLA compile
def test_split_heavy_buckets(rng):
    # depth 20: midpoint splitters spend ~4 levels shaving empty halves
    # before reaching the 0.01-wide cluster (the paper's midpoint-vs-median
    # observation), so give SplitLeaf room to finish.
    dps = _mk(rng, depth=20)
    burst = jnp.asarray(0.3 + 0.01 * rng.random((2000, 3)), jnp.float32)
    dps = dynamic.insert(dps, burst, jnp.ones(2000, jnp.float32))
    assert int(dynamic.max_bucket_occupancy(dps)) > 2 * 32
    dps = dynamic.adjustments(dps)
    assert int(dynamic.max_bucket_occupancy(dps)) <= 2 * 32
    assert _conserved(dps)


def test_merge_light_buckets(rng):
    dps = _mk(rng)
    ids = np.nonzero(np.asarray(dps.active))[0]
    rng.shuffle(ids)
    dps = dynamic.delete(dps, jnp.asarray(ids[:900]))
    nb0 = int(dynamic.num_buckets(dps))
    dps = dynamic.adjustments(dps)
    nb1 = int(dynamic.num_buckets(dps))
    assert nb1 < nb0, f"merge should reduce buckets: {nb0} -> {nb1}"
    assert _conserved(dps)


@given(seed=st.integers(0, 1000), frac=st.floats(0.1, 0.9))
@settings(max_examples=8, deadline=None)
def test_property_adjustments_conserve(seed, frac):
    rng = np.random.default_rng(seed)
    dps = _mk(rng)  # shared shape with the other tests: one compile
    new = jnp.asarray(rng.random((400, 3)).astype(np.float32) * 0.2)
    dps = dynamic.insert(dps, new, jnp.ones(400, jnp.float32))
    ids = np.nonzero(np.asarray(dps.active))[0]
    kill = ids[: int(len(ids) * frac)]
    dps = dynamic.delete(dps, jnp.asarray(kill))
    dps = dynamic.adjustments(dps)
    assert _conserved(dps)


def test_amortized_controller_alg3():
    """Credits = LB cost; rebalance triggers when cumulative excess
    exceeds credits (Algorithm 3 semantics)."""
    c = dynamic.AmortizedController()
    c.balanced(lb_cost=5.0, num_buckets=100, timeop=0.01)
    # constant cost: never triggers
    assert not any(c.observe(0.01, 100) for _ in range(50))
    # drifting cost accumulates delta = sum(cost - base)
    c2 = dynamic.AmortizedController()
    c2.balanced(lb_cost=5.0, num_buckets=100, timeop=0.01)
    fired = [c2.observe(0.01 + 0.001 * i, 100) for i in range(40)]
    assert True in fired
    i = fired.index(True)
    # delta at trigger must exceed credits
    assert c2.delta > 5.0
    assert i > 5  # amortization delays the trigger


def test_controller_more_credits_fewer_rebalances():
    def run(lb_cost):
        c = dynamic.AmortizedController()
        c.balanced(lb_cost=lb_cost, num_buckets=100, timeop=0.01)
        n = 0
        for i in range(200):
            if c.observe(0.011 + 0.0005 * (i % 37), 100):
                c.balanced(lb_cost=lb_cost, num_buckets=100, timeop=0.01)
                n += 1
        return n

    assert run(20.0) <= run(2.0)
