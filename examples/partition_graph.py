"""General graph partitioning + distributed SpMV (paper §V-B).

Builds a power-law graph, compares row-wise vs SFC partitions on the
paper's Table II-VII metrics, and executes the reduce-scatter SpMV.

    PYTHONPATH=src python examples/partition_graph.py

``REPRO_EXAMPLE_SMOKE=1`` shrinks sizes for the CI examples-smoke job.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spmv
from repro.launch.mesh import make_mesh

n = 4_000 if os.environ.get("REPRO_EXAMPLE_SMOKE", "0") == "1" else 50_000
src, dst = spmv.powerlaw_graph(n, 10, seed=7)
print(f"graph: {n} vertices, {len(src)} edges (power-law)")

P = 16
prow = spmv.rowwise_partition(src, n, P)
psfc = spmv.sfc_partition(src, dst, n, P)
m_r = spmv.communication_metrics(prow, src, dst, n, P, improve=False)
m_s = spmv.communication_metrics(psfc, src, dst, n, P)
hdr = f"{'':10s} {'AvgLoad':>9s} {'MaxLoad':>9s} {'MaxDegree':>9s} {'MaxEdgeCut':>10s}"
print(hdr)
for name, m in (("row-wise", m_r), ("sfc", m_s)):
    print(
        f"{name:10s} {m['AvgLoad']:9d} {m['MaxLoad']:9d} "
        f"{m['MaxDegree']:9d} {m['MaxEdgeCut']:10d}"
    )

# executable distributed SpMV on however many devices exist
rng = np.random.default_rng(0)
vals = rng.random(len(src)).astype(np.float32)
x = jnp.asarray(rng.random(n), jnp.float32)
Pd = min(8, jax.device_count())
mesh = make_mesh((Pd,), ("parts",))
part = spmv.sfc_partition(src, dst, n, Pd)
y = spmv.distributed_spmv(mesh, "parts", src, dst, vals, part, x, n)
yref = spmv.spmv_reference(src, dst, vals, x, n)
print(f"\ndistributed SpMV on {Pd} shards: max err {float(jnp.max(jnp.abs(y-yref))):.2e}")
