"""AMR heat stencil on the partition core — the paper's mesh workload,
end to end.

A moving load feature drives quadtree refinement and per-cell cost
drift; the hierarchical repartitioner re-slices as it moves; migration
plans carry cell state to its new owners; compiled halo plans execute
the distributed stencil — and the result is checked BIT-EXACTLY against
the single-device reference.

    PYTHONPATH=src python examples/amr_stencil.py

Runs on however many devices exist (8 fake host devices recommended:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); arranges them
as 2 nodes x D/2 devices when the count is even, flat otherwise.
``REPRO_EXAMPLE_SMOKE=1`` shrinks sizes for CI.
"""
import os

SMOKE = os.environ.get("REPRO_EXAMPLE_SMOKE", "0") == "1"

import jax
import numpy as np

from repro.core import partitioner
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.mesh import simulate

cfg = simulate.SimConfig(
    events=6 if SMOKE else 10,
    amr_every=3,
    substeps=2,
    base_level=3 if SMOKE else 4,
    max_level=5 if SMOKE else 6,
)
events = simulate.build_trajectory(cfg)
print(f"trajectory: {len(events)} events, cells {events[0].mesh.n} -> {events[-1].mesh.n}")
for ev in events:
    if ev.transfer is not None:
        print(
            f"  t={ev.t}: refine/coarsen -> {ev.mesh.n} cells "
            f"(+{int(ev.transfer.born.sum())} born, "
            f"-{ev.transfer.died_idx.size} died), levels "
            f"{np.bincount(ev.mesh.level.astype(int))[cfg.base_level:]}"
        )

u0 = simulate.initial_field(events[0].mesh, cfg)
uref = simulate.run_reference(events, u0, cfg.substeps)

ndev = jax.device_count()
if ndev % 2 == 0 and ndev >= 4:
    hplan = partitioner.HierarchyPlan(num_nodes=2, devices_per_node=ndev // 2)
    mesh = shd.make_node_device_mesh(2, ndev // 2)
else:
    hplan = partitioner.HierarchyPlan(num_nodes=1, devices_per_node=ndev)
    mesh = make_mesh((ndev,), (hplan.device_axis,))
print(f"\ndevice mesh: {hplan.num_nodes} nodes x {hplan.devices_per_node} devices")

u, st = simulate.run_distributed(
    events, u0, cfg.substeps, mesh, hplan, driver="incremental", cfg=cfg
)
print(
    f"closed loop: {st.repartition_events} repartition events "
    f"({st.amr_events} AMR, {st.intra_reslices} intra-node re-slices, "
    f"{st.inter_reslices} inter-node, {st.rebuilds} rebuilds)"
)
print(
    f"migration: {st.moved_total} cells moved, {st.moved_inter_node} across "
    f"nodes, {st.node_local_moves} exchanges provably node-local"
)
hm = st.halo_metrics
print(
    f"halo quality: MaxSurfaceIndex={hm['MaxSurfaceIndex']:.3f} "
    f"MaxEdgeCut={hm['MaxEdgeCut']:.0f} MaxDegree={hm['MaxDegree']} "
    f"inter-node ghosts {hm['InterNodeGhosts']}/{hm['TotalGhosts']}"
)
exact = np.array_equal(uref, u)
print(f"\ndistributed result bit-equal to single-device reference: {exact}")
assert exact, float(np.abs(uref - u).max())
