"""Quickstart: partition a point cloud with the SFC partitioner and
inspect the paper's quality metrics.

    PYTHONPATH=src python examples/quickstart.py

``REPRO_EXAMPLE_SMOKE=1`` shrinks sizes for the CI examples-smoke job.
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.core import metrics, partitioner

rng = np.random.default_rng(0)
half = 2_000 if os.environ.get("REPRO_EXAMPLE_SMOKE", "0") == "1" else 30_000

# a clustered 3-D point cloud with non-uniform weights
pts = np.concatenate(
    [rng.normal(0.2, 0.03, (half, 3)), rng.random((half, 3))]
).astype(np.float32)
weights = (rng.random(2 * half) + 0.5).astype(np.float32)

for curve in ("morton", "hilbert"):
    cfg = partitioner.PartitionerConfig(curve=curve, stats="rank")
    res = partitioner.partition(jnp.asarray(pts), jnp.asarray(weights), num_parts=16, cfg=cfg)
    loads = np.asarray(res.loads)
    cross = metrics.knn_cross_fraction(pts, np.asarray(res.part), k=4, sample=1024)
    print(
        f"{curve:8s} imbalance={loads.max()-loads.min():8.3f} "
        f"(max element weight {weights.max():.3f})  kNN-cut={cross:.3f}"
    )

print("\nPartitions are contiguous curve slices; the load guarantee is the")
print("paper's: any two parts differ by at most ~one max element weight.")

# --- the bucket-statistics path (paper's full pipeline) -----------------
# The partition is computed from O(B) kd-tree bucket summaries: buckets
# are SFC-ordered by centroid key, the knapsack slices bucket weights,
# points inherit their bucket's part by gather. No per-point sort runs
# (res.perm is None) — the balance granularity is one bucket.
cfg = partitioner.PartitionerConfig(use_tree=True, max_depth=12)
res = partitioner.partition(jnp.asarray(pts), jnp.asarray(weights), num_parts=16, cfg=cfg)
loads = np.asarray(res.loads)
nb = int(np.asarray(res.bucket_order.num_buckets))
cross = metrics.knn_cross_fraction(pts, np.asarray(res.part), k=4, sample=1024)
print(
    f"\ntree     imbalance={loads.max()-loads.min():8.3f} "
    f"(max bucket weight {float(np.asarray(res.summary.weight).max()):.3f}, "
    f"{nb} buckets)  kNN-cut={cross:.3f}  perm={res.perm}"
)

# --- the hierarchical (node -> device) decomposition --------------------
# The paper's hybrid model: a coarse knapsack assigns curve slices to
# NODES, then each node independently re-knapsacks its slice across its
# local DEVICES — same bucket statistics, same frozen frame, two nested
# slices. part = node * devices_per_node + device; a (1, D) plan is
# bit-identical to the flat partition above.
plan = partitioner.HierarchyPlan(num_nodes=4, devices_per_node=4)
hres = partitioner.hierarchical_partition(
    jnp.asarray(pts), jnp.asarray(weights), plan, cfg
)
node_loads = np.asarray(hres.node_loads)
dev_loads = np.asarray(hres.loads).reshape(plan.num_nodes, plan.devices_per_node)
print(f"\nhierarchy {plan.num_nodes} nodes x {plan.devices_per_node} devices:")
for j in range(plan.num_nodes):
    devs = " ".join(f"{x:8.1f}" for x in dev_loads[j])
    print(f"  node {j}: load={node_loads[j]:9.1f}   devices: {devs}")
print(
    f"  node spread={node_loads.max()-node_loads.min():.3f}, "
    f"device spread within worst node="
    f"{float((dev_loads.max(1)-dev_loads.min(1)).max()):.3f} "
    f"(both <= ~2x max bucket weight)"
)
