"""End-to-end driver (deliverable b): train the ~135M-param smollm-135m
for a few hundred steps on the synthetic pipeline, with checkpointing and
resume. At CPU scale we use a shortened sequence; the model is the REAL
135M config (30 layers, d=576, GQA 9/3, tied embeddings).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import tempfile

from repro.configs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "repro_smollm_ckpt")
    cfg = ARCHS["smollm-135m"]
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("e2e", args.seq, args.batch, "train"),
        learning_rate=6e-4,
        warmup_steps=20,
        schedule="cosine",
    )
    out = train_loop(run, steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=100, log_every=20)
    drop = out["first_loss"] - out["final_loss"]
    print(
        f"\nsmollm-135m ({cfg.param_count()/1e6:.0f}M params): "
        f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
        f"(drop {drop:.3f}) over {out['steps']} steps"
    )
    assert drop > 0, "loss must decrease"


if __name__ == "__main__":
    main()
