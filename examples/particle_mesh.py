"""Coupled particle-mesh (PIC-style) on the partition core — ONE
partition carrying two entity kinds, end to end.

Mesh cells register as a static anchor prefix and particles as mobile
rows in the SAME hierarchical repartitioner; one interaction plan
carries both the cell stencil lanes and the particle pair lanes, and
one migration moves field + position + velocity + mass together. The
particles deposit drag onto the field at coupling events, crossers
re-register through the engine's insert/delete path, the Alg. 3
trigger answers the load drift — and the final mesh field AND particle
trajectories are checked BIT-EXACTLY against the single-device
reference.

    PYTHONPATH=src python examples/particle_mesh.py

Runs on however many devices exist (8 fake host devices recommended:
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); arranges them
as 2 nodes x D/2 devices when the count is even, flat otherwise.
``REPRO_EXAMPLE_SMOKE=1`` shrinks sizes for CI.
"""
import os

SMOKE = os.environ.get("REPRO_EXAMPLE_SMOKE", "0") == "1"

import jax
import numpy as np

from repro.core import partitioner
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.particles import pic

cfg = pic.PICSimConfig(
    n=128 if SMOKE else 256,
    events=4 if SMOKE else 8,
    substeps=2,
    mesh_level=3,
)
print(
    f"coupled run: {1 << (cfg.d * cfg.mesh_level)} cells + {cfg.n} "
    f"particles, {cfg.events} events x {cfg.substeps} substeps, "
    f"coupling every {cfg.couple_every} events"
)

u_ref, ps_ref = pic.run_reference_coupled(cfg)

ndev = jax.device_count()
if ndev % 2 == 0 and ndev >= 4:
    hplan = partitioner.HierarchyPlan(num_nodes=2, devices_per_node=ndev // 2)
    mesh = shd.make_node_device_mesh(2, ndev // 2)
else:
    hplan = partitioner.HierarchyPlan(num_nodes=1, devices_per_node=ndev)
    mesh = make_mesh((ndev,), (hplan.device_axis,))
print(f"device mesh: {hplan.num_nodes} nodes x {hplan.devices_per_node} devices")

u, ps, st = pic.run_distributed_coupled(
    cfg, mesh, hplan, driver="incremental"
)
print(
    f"closed loop: {st.repartition_events} repartition events, "
    f"{st.registration_events} registration events "
    f"({st.crossers_total} boundary crossers re-registered), "
    f"{st.intra_reslices} intra-node re-slices, {st.rebuilds} rebuilds"
)
print(
    f"one partition, two entity kinds: {st.n_cells} anchor cells + "
    f"{cfg.n} particles, widest interaction table K={st.k_max}"
)

exact = (
    np.array_equal(u_ref, u)
    and np.array_equal(ps_ref.pos, ps.pos)
    and np.array_equal(ps_ref.vel, ps.vel)
)
print(f"\nfield + trajectories bit-equal to single-device reference: {exact}")
assert exact
