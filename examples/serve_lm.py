"""Serve a small model with batched requests (deliverable b).

Demonstrates the knapsack admission batcher: requests with mixed prompt
lengths are grouped into balanced decode batches (paper §III-C applied to
serving), then greedily decoded against the KV cache.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serve.engine import Engine, Request, knapsack_batches

rng = np.random.default_rng(1)
cfg = reduced(ARCHS["smollm-135m"])
params = M.get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))

reqs = [
    Request(
        rid=i,
        prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 48)).astype(np.int32),
        max_new_tokens=6,
    )
    for i in range(16)
]
batches = knapsack_batches(reqs, batch_size=4)
print("admission batches (total prompt tokens per batch):")
for i, b in enumerate(batches):
    print(f"  batch {i}: {[r.rid for r in b]} load={sum(r.length for r in b)}")

engine = Engine(cfg, params, max_seq=96, batch_size=4)
results = engine.run(reqs)
for rid in sorted(results)[:4]:
    print(f"req {rid} -> {results[rid]}")
print(f"completed {len(results)}/16 requests")
