"""MoE expert balancing with the partitioner (DESIGN.md §3).

Shows: (1) knapsack-curve token dispatch inside the MoE layer, (2) the
amortized controller deciding WHEN to re-place experts, (3) the knapsack
expert re-placement plan and its migration cost.

    PYTHONPATH=src python examples/moe_balance.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.dynamic import AmortizedController
from repro.models import moe as Mo

cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], num_experts=16, num_experts_per_tok=4)
key = jax.random.PRNGKey(0)
p = Mo.moe_init(key, cfg, jnp.float32)

controller = AmortizedController()
controller.balanced(lb_cost=10.0, num_buckets=16, timeop=1.0)

print("step | max/mean expert load | rebalance?")
for step in range(8):
    # drift the input distribution so routing skews over time
    x = jax.random.normal(jax.random.fold_in(key, step), (4, 64, cfg.d_model))
    x = x + 0.4 * step * jnp.ones((cfg.d_model,))
    load = np.asarray(Mo.expert_load(p, x, cfg))
    skew = load.max() / max(load.mean(), 1)
    fire = controller.observe(float(skew), 16)
    print(f"{step:4d} | {skew:20.2f} | {fire}")
    if fire:
        part, plan = Mo.rebalance_expert_placement(jnp.asarray(load, jnp.float32), 4)
        shard_loads = np.bincount(np.asarray(part), weights=load, minlength=4)
        print(
            f"     -> re-placed experts onto 4 EP shards: loads={shard_loads.astype(int)} "
            f"(moved {plan.total_moved} experts, {plan.rounds} bounded rounds)"
        )
        controller.balanced(lb_cost=10.0, num_buckets=16, timeop=float(skew))
