"""MoE expert balancing driven by the incremental repartitioning engine.

The first real dynamic workload for `repro.core.repartition`: experts are
elements on the space-filling curve (placed by their router-embedding
projection, so similar experts sit near each other and co-locate), their
weight is the measured token load. Each step the router skews further;
the engine re-slices the cached curve incrementally, and the amortized
controller (paper Alg. 3) fires a full rebuild only when accumulated
imbalance exhausts the banked credits.

    PYTHONPATH=src python examples/moe_balance.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.repartition import Repartitioner
from repro.models import moe as Mo

EP_SHARDS = 4

cfg = reduced(ARCHS["qwen3-moe-30b-a3b"], num_experts=16, num_experts_per_tok=4)
key = jax.random.PRNGKey(0)
p = Mo.moe_init(key, cfg, jnp.float32)

# place each expert on the curve by a 2-D projection of its router column:
# nearby experts (similar routing directions) land in the same part, so a
# rebalance shifts whole "topic" neighborhoods between EP shards
router = np.asarray(p["router"], np.float32)              # (D, E)
proj = np.asarray(jax.random.normal(jax.random.fold_in(key, 7), (router.shape[0], 2)))
expert_xy = jnp.asarray(router.T @ proj, jnp.float32)     # (E, 2)

x0 = jax.random.normal(jax.random.fold_in(key, 100), (4, 64, cfg.d_model))
load0 = np.asarray(Mo.expert_load(p, x0, cfg)).astype(np.float32)

engine = Repartitioner(
    expert_xy,
    jnp.asarray(load0 + 1.0),
    num_parts=EP_SHARDS,
    max_depth=6,
    bucket_size=2,
)

print("step | max/mean expert-shard load | action      | experts moved")
for step in range(12):
    # drift the input distribution so routing skews over time
    x = jax.random.normal(jax.random.fold_in(key, step), (4, 64, cfg.d_model))
    x = x + 0.4 * step * jnp.ones((cfg.d_model,))
    load = np.asarray(Mo.expert_load(p, x, cfg)).astype(np.float32)

    engine.update_weights(jnp.asarray(load + 1.0))
    out = engine.step()

    part = np.asarray(out.part)[: cfg.num_experts]
    shard_loads = np.bincount(part, weights=load, minlength=EP_SHARDS)
    print(
        f"{step:4d} | {out.imbalance:26.3f} | {out.kind:<11s} | "
        f"{out.plan.total_moved} ({out.plan.rounds} bounded rounds)"
    )

print(
    f"\nengine: {engine.stats.rebuilds} rebuilds, "
    f"{engine.stats.incremental_steps} incremental steps, "
    f"{engine.stats.keygen_points} storage slots through key-gen "
    f"(a rebuild-every-step policy would have paid "
    f"{engine.capacity * (engine.stats.rebuilds + engine.stats.incremental_steps)})"
)
