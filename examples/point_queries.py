"""Query serving over a drifting point set (paper §V-A end to end).

Build one Repartitioner, serve point-location / kNN traffic from its
versioned CurveIndex through the DistributedQueryEngine, drift the
geometry (inserts), and watch the engine swap index versions live —
no cold rebuild, no second key generation. With 8+ devices (or
XLA_FLAGS=--xla_force_host_platform_device_count=8) the second half
serves a Zipf-hot stream on a mesh, replicates the hot buckets, and
shrinks the device pool under the live engine.

    PYTHONPATH=src python examples/point_queries.py
"""
import os

if os.environ.get("REPRO_EXAMPLE_SMOKE") == "1" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import queries
from repro.core.partitioner import PartitionerConfig
from repro.core.repartition import Repartitioner
from repro.serve.query_engine import DistributedQueryEngine, QueryRequest


def main():
    rng = np.random.default_rng(0)
    n = 50_000
    pts = jnp.asarray(rng.random((n, 3)), jnp.float32)

    rp = Repartitioner(
        pts, None, num_parts=16, cfg=PartitionerConfig(curve="morton"),
        capacity=2 * n,
    )
    eng = DistributedQueryEngine(rp.curve_index(), max_batch_rows=8192)
    print(f"index v{eng.version}: {int(rp.curve_index().valid_count())} points, "
          f"{rp.curve_index().num_buckets} buckets")

    # mixed query traffic, knapsack-batched into balanced rounds
    reqs = []
    for i in range(12):
        m = int(rng.integers(50, 4000))
        if i % 3 == 0:
            reqs.append(QueryRequest(i, rng.random((m, 3)).astype(np.float32), "knn", k=3))
        else:
            sel = rng.choice(n, m, replace=True)
            reqs.append(QueryRequest(i, np.asarray(pts)[sel], "pl"))
    results = eng.run(reqs)
    hits = sum(int(np.asarray(results[r.rid].found).sum())
               for r in reqs if r.kind == "pl")
    total_pl = sum(r.rows for r in reqs if r.kind == "pl")
    print(f"served {eng.stats.queries_served} queries in {eng.stats.rounds} rounds "
          f"(rebatches={eng.stats.rebatches}); point-location hits {hits}/{total_pl}")

    # drift: insert a hot cluster, then refresh the serving index live
    new_pts = jnp.asarray(0.4 + 0.05 * rng.random((2_000, 3)), jnp.float32)
    slots = rp.insert(new_pts, jnp.ones(2_000))
    swapped = eng.maybe_refresh(rp)
    f = eng.point_location(new_pts[:512])
    print(f"after insert: swapped={swapped} -> index v{eng.version}, "
          f"new points found {int(f.found.sum())}/512, "
          f"keys generated for delta only: {rp.stats.keygen_points - 2 * n} "
          f"(engine capacity {rp.capacity})")

    # the migration step keeps serving correct: rebalance + re-query
    step = rp.step()
    eng.maybe_refresh(rp)
    d, g = eng.knn(new_pts[:256], k=3)
    print(f"step kind={step.kind}, moved={step.plan.total_moved}; "
          f"knn mean distance {float(np.asarray(d).mean()):.4f} at v{eng.version}")

    if len(jax.devices()) >= 8:
        skewed_serving(rp, rng)


def skewed_serving(rp, rng):
    """Zipf-hot traffic on a mesh: bounded lanes, hot-bucket replication,
    then an elastic shrink of the device pool — answers bit-equal
    throughout."""
    from repro.launch.mesh import make_mesh
    from repro.runtime.elastic import ElasticServingController

    idx = rp.curve_index()
    mesh = make_mesh((8,), ("data",))
    eng = DistributedQueryEngine(idx, mesh, "data", lane_rows=16, hit_decay=1.0)

    starts = np.asarray(idx.bucket_starts)
    B = idx.num_buckets
    zipf = 1.0 / np.arange(1, B + 1)
    bw = np.zeros(B)
    bw[rng.permutation(B)] = zipf / zipf.sum()
    rows = [int(rng.integers(starts[b], starts[b + 1]))
            for b in rng.choice(B, 2048, p=bw) if starts[b + 1] > starts[b]]
    qz = jnp.asarray(np.asarray(idx.points)[rows], jnp.float32)

    ref = eng.point_location(qz)
    r_contig = eng.stats.route_rounds
    hot = eng.replicate_hot(top_k=8)
    got = eng.point_location(qz)
    assert np.array_equal(np.asarray(got.ids), np.asarray(ref.ids))
    print(f"zipf on 8 shards: {r_contig} routing rounds contiguous -> "
          f"{eng.stats.route_rounds - r_contig} with {len(hot)} hot buckets "
          f"replicated ({eng.stats.annex_served} annex answers, bit-equal)")

    ctl = ElasticServingController(rp, eng, devices=jax.devices()[:8])
    ev = ctl.apply_device_change(jax.devices()[:6])
    got6 = eng.point_location(qz)
    assert np.array_equal(np.asarray(got6.ids), np.asarray(ref.ids))
    print(f"elastic 8->6: reshard in {ev.seconds*1e3:.0f} ms, "
          f"moved {ev.moved_units} units, cold rebuilds {ev.rebuilds_during}, "
          f"answers unchanged at v{eng.version}")


if __name__ == "__main__":
    main()
